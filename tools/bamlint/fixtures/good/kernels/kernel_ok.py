# bamlint-fixture: clean
# Well-formed Pallas site: index-map arity matches grid rank, stores go
# to the output ref, constructors carry explicit dtypes.
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _k(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2


def run(x):
    return pl.pallas_call(
        _k,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32),
    )(x)


def accumulator(n):
    return jnp.zeros((n, 4), jnp.float32)
