# bamlint-fixture: clean
# Conserved metrics: every field classified, surfaced in summary(), and
# constructed in zeros().
class IOMetrics:
    requests: object
    dropped: object
    max_depth: object

    @staticmethod
    def zeros():
        return IOMetrics(requests=0, dropped=0, max_depth=0)

    def summary(self):
        return {
            "requests": self.requests,
            "dropped": self.dropped,
            "max_depth": self.max_depth,
        }


WATERMARK_FIELDS = ("max_depth",)
ADDITIVE_FIELDS = ("requests", "dropped")
