# bamlint-fixture: clean
# Well-formed token lifecycles: every submit's token reaches exactly one
# wait (or escapes by return), pins pair with releases.
from repro.core import cache as C


def submit_wait(arr, st, req):
    st, tok = arr.submit(st, req)
    st, vals = arr.wait(st, tok)
    return st, vals


def pipelined(arr, st, reqs):
    st, tok = arr.submit(st, reqs[0])
    for r in reqs[1:]:
        st, nxt = arr.submit(st, r)
        st, vals = arr.wait(st, tok)
        tok = nxt
    st, vals = arr.wait(st, tok)
    return st, vals


def handoff(arr, st, req):
    st, tok = arr.submit(st, req)
    return st, tok


def pin_paired(cache, slots):
    cache = C.acquire(cache, slots)
    out = transform(cache)
    cache = C.release(cache, slots)
    return cache, out
