# bamlint-fixture: suppressed BAM105
# The violation below is real but deliberately waived inline; bamlint
# must honor the suppression (and re-flag it under --no-suppress).
import jax


def driver(arr, st, idx):
    read = jax.jit(arr.read)  # bamlint: ignore[BAM105]
    v, st = read(st, idx)
    return v, st
