"""Pass 1 — host-sync / retrace hazards inside jit-reachable code.

These are the exact patterns behind the submit/wait control-path overhead
the hot-path benchmark tracks (BENCH_hot_path.json): a hidden host sync
serializes the submission window; a shape-dependent Python branch or a
per-call ``jax.jit`` wrapper forces a retrace/recompile on every op.

Rules
-----
BAM101  ``.block_until_ready()`` inside jit-reachable code — a host sync
        on the request path.
BAM102  host transfer of a traced value inside jit-reachable code:
        ``.item()`` / ``.tolist()``, or ``float()``/``int()``/``bool()``/
        ``np.asarray()``/``np.array()`` applied to a tracer-derived value.
BAM103  ``jax.debug.print`` / ``pl.debug_print`` / ``print`` inside a
        Pallas kernel body.
BAM104  Python ``if``/``while``/``for`` control flow conditioned on a
        tracer-derived value inside jit-reachable code (forces a retrace
        per distinct value, or a ConcretizationError).
BAM105  ``jax.jit(...)`` created inside a function body: a fresh wrapper
        per call defeats the compilation cache — hoist it to module level,
        bind it to ``self.<attr>`` once, or use the instance's jit-cached
        op family (``read_jit``/``submit_jit``/``wait_jit``).
"""
from __future__ import annotations

import ast
from typing import List

from tools.bamlint.core import Finding, ModuleInfo
from tools.bamlint.reach import (
    FuncNode, ModuleAnalysis, TaintTracker, dotted, tail,
)

RULES = {
    "BAM101": "host sync (.block_until_ready) inside jit-reachable code",
    "BAM102": "host transfer of a traced value inside jit-reachable code",
    "BAM103": "debug print inside a Pallas kernel",
    "BAM104": "Python control flow on a traced value inside jit-reachable "
              "code",
    "BAM105": "per-call jax.jit wrapper defeats the compilation cache",
}

HOST_CAST_FNS = {"float", "int", "bool"}
NP_TRANSFER = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}


def check(mod: ModuleInfo) -> List[Finding]:
    analysis = ModuleAnalysis(mod.tree)
    out: List[Finding] = []

    # BAM105 applies to every function body, traced or host-side: the
    # per-call wrapper hazard lives precisely in host driver loops.
    for fi in analysis.funcs.values():
        # a nested `@jax.jit def f` re-traces on every call of the
        # enclosing function — same per-call-wrapper hazard.
        if fi.parent is not None:
            for dec in getattr(fi.node, "decorator_list", []):
                is_jit = tail(dotted(dec)) == "jit" or (
                    isinstance(dec, ast.Call)
                    and tail(dotted(dec.func)) == "partial"
                    and any(tail(dotted(a)) == "jit" for a in dec.args))
                if is_jit:
                    out.append(mod.finding(
                        "BAM105", dec,
                        "`@jax.jit` on a function nested inside another "
                        "function: every call of the outer function "
                        "builds a fresh wrapper and recompiles; hoist "
                        "the jitted step to module level or cache it "
                        "per instance"))
        tt = TaintTracker(fi)
        for node in tt.walk_own():
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, (ast.Name, ast.Attribute)) and \
                    tail(dotted(node.func)) == "jit" and \
                    not _is_self_bound_jit(node, fi):
                out.append(mod.finding(
                    "BAM105", node,
                    "`jax.jit` wrapper created inside a function: a "
                    "fresh wrapper per call recompiles at every "
                    "invocation; hoist to module level, bind once to "
                    "`self.<attr>`, or use the instance's *_jit() "
                    "cached op family"))

    for fi in analysis.reachable_functions():
        tt = TaintTracker(fi)
        in_kernel = fi.kernel_reachable
        for node in tt.walk_own():
            if isinstance(node, ast.Call):
                fname = dotted(node.func)
                t = tail(fname)
                if t == "block_until_ready":
                    out.append(mod.finding(
                        "BAM101", node,
                        "host sync `.block_until_ready()` inside "
                        "jit-reachable code serializes the submission "
                        "window; sync at the host call site instead"))
                elif t in ("item", "tolist"):
                    out.append(mod.finding(
                        "BAM102", node,
                        f"`.{t}()` transfers a traced value to the host "
                        "inside jit-reachable code (device round-trip per "
                        "op); keep the value on device or move this to "
                        "the host call site"))
                elif t in HOST_CAST_FNS and isinstance(node.func, ast.Name):
                    if node.args and tt.expr_tainted(node.args[0]):
                        out.append(mod.finding(
                            "BAM102",
                            node,
                            f"`{t}()` of a traced value inside "
                            "jit-reachable code forces a host sync "
                            "(ConcretizationError under jit); use jnp "
                            "ops or hoist to the host call site"))
                elif fname in NP_TRANSFER:
                    if node.args and tt.expr_tainted(node.args[0]):
                        out.append(mod.finding(
                            "BAM102", node,
                            f"`{fname}()` of a traced value inside "
                            "jit-reachable code is a device->host "
                            "transfer; use jnp.asarray or hoist"))
                elif in_kernel and (
                        fname in ("jax.debug.print", "debug.print")
                        or t == "debug_print"
                        or (t == "print"
                            and isinstance(node.func, ast.Name))):
                    out.append(mod.finding(
                        "BAM103", node,
                        "debug print inside a Pallas kernel body — "
                        "serializes the kernel and breaks on TPU; strip "
                        "it before it reaches the hot path"))
            elif isinstance(node, ast.If) or isinstance(node, ast.While):
                if tt.expr_tainted(node.test):
                    out.append(mod.finding(
                        "BAM104", node,
                        "Python `if`/`while` on a traced value inside "
                        "jit-reachable code — retraces per value or "
                        "raises under jit; use jnp.where / lax.cond"))
            elif isinstance(node, ast.For):
                if tt.expr_tainted(node.iter) and \
                        not _is_container_iteration(node):
                    out.append(mod.finding(
                        "BAM104", node,
                        "Python `for` over a traced value inside "
                        "jit-reachable code — unrolls/retraces per "
                        "shape; use lax.scan / lax.fori_loop"))
    return out


def _is_container_iteration(node: ast.For) -> bool:
    """True for pytree-container loops that are static under jit despite a
    tainted iterable: dict-key iteration (``for k in aux: aux[k] ...``),
    iteration over a subscripted container (``cache["layers"]``), and
    ``enumerate``/``zip``/``reversed`` over such shapes.  Loop count is a
    trace-time constant in all of these — not a retrace hazard."""
    iters: List[ast.expr] = [node.iter]
    it = node.iter
    if isinstance(it, ast.Call) and isinstance(it.func, ast.Name) and \
            it.func.id in ("enumerate", "zip", "reversed"):
        iters = list(it.args)
    for e in iters:
        if isinstance(e, ast.Subscript):
            continue
        if isinstance(e, ast.Name):
            # dict-key idiom: the target indexes back into the iterable
            tgt_names = {n.id for n in ast.walk(node.target)
                         if isinstance(n, ast.Name)}
            keyed = any(
                isinstance(s, ast.Subscript) and
                isinstance(s.value, ast.Name) and s.value.id == e.id and
                isinstance(s.slice, ast.Name) and s.slice.id in tgt_names
                for b in node.body for s in ast.walk(b))
            if keyed:
                continue
        return False
    return True


def _is_self_bound_jit(call: ast.Call, fi) -> bool:
    """True when the jit result is cached on the instance
    (``self.x = jax.jit(...)``) — a once-per-object wrapper, not
    per-call."""
    node = fi.node
    for stmt in ast.walk(node):
        if isinstance(stmt, ast.Assign) and stmt.value is call:
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Attribute) and \
                        isinstance(tgt.value, ast.Name) and \
                        tgt.value.id in ("self", "cls"):
                    return True
    return False
