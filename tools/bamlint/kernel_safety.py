"""Pass 3 — Pallas kernel safety: grid/BlockSpec shape discipline, ref
aliasing, and accidental float64 promotion.

These bugs do not fail loudly in ``interpret=True`` CI (interpret mode is
forgiving about tiling, and x64 is off by default) but break or silently
mis-tile the moment a kernel reaches a real TPU or an x64-enabled host.

Rules
-----
BAM301  grid/BlockSpec mismatch: an index-map whose arity disagrees with
        the grid rank (+ ``num_scalar_prefetch``), a block shape whose
        rank disagrees with the index-map's returned tuple, a literal
        block dim that does not divide the corresponding literal array
        dim, or ``out_specs``/``out_shape`` length disagreement.
BAM302  store into an *input* ref inside a kernel body without a
        matching ``input_output_aliases`` entry — in-place mutation of a
        possibly-donated input buffer.
BAM303  dtype-less array constructor (``jnp.zeros``/``ones``/``full``
        with float fill/float ``arange``/float ``array``) in a kernels
        module — promotes to float64 under ``jax_enable_x64`` and breaks
        TPU lowering.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from tools.bamlint.core import Finding, ModuleInfo
from tools.bamlint.reach import dotted, tail

RULES = {
    "BAM301": "grid/BlockSpec shape or arity mismatch in pallas_call",
    "BAM302": "store into an input ref without input_output_aliases",
    "BAM303": "dtype-less array constructor promotes to f64 under x64",
}


def _is_kernels_module(mod: ModuleInfo) -> bool:
    return "kernels" in mod.path.parts


def _has_pallas_call(mod: ModuleInfo) -> bool:
    return "pallas_call" in mod.source


def check(mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    if _has_pallas_call(mod):
        out.extend(_check_pallas_calls(mod))
    if _is_kernels_module(mod):
        out.extend(_check_f64(mod))
    return out


# --------------------------------------------------------------- helpers
def _int_literal(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) and \
            not isinstance(node.value, bool):
        return node.value
    return None


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _as_spec_list(node: Optional[ast.expr]) -> Optional[List[ast.expr]]:
    """Normalize an in_specs/out_specs expression to a list of BlockSpec
    expressions when statically resolvable (handles ``[spec] * 6``)."""
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        for lhs, rhs in ((node.left, node.right), (node.right, node.left)):
            n = _int_literal(rhs)
            if n is not None and isinstance(lhs, (ast.List, ast.Tuple)):
                return list(lhs.elts) * n
    if isinstance(node, ast.Call) and tail(dotted(node.func)) == "BlockSpec":
        return [node]
    return None


def _blockspec_parts(spec: ast.expr) -> Tuple[Optional[ast.expr],
                                              Optional[ast.expr]]:
    """(block_shape_expr, index_map_expr) of a BlockSpec call, or Nones."""
    if not (isinstance(spec, ast.Call) and
            tail(dotted(spec.func)) == "BlockSpec"):
        return None, None
    shape = spec.args[0] if len(spec.args) >= 1 else \
        _kwarg(spec, "block_shape")
    imap = spec.args[1] if len(spec.args) >= 2 else _kwarg(spec, "index_map")
    return shape, imap


def _lambda_arity(fn: ast.expr) -> Optional[Tuple[int, int, bool]]:
    """(min-arity, max-arity, has_vararg) for a Lambda index map.
    Defaulted params (the ``g=group`` closure-capture idiom) widen the
    accepted range rather than shifting it."""
    if isinstance(fn, ast.Lambda):
        a = fn.args
        total = len(a.args) + len(a.posonlyargs)
        return total - len(a.defaults), total, a.vararg is not None
    return None


def _lambda_ret_len(fn: ast.expr) -> Optional[int]:
    if isinstance(fn, ast.Lambda) and \
            isinstance(fn.body, (ast.Tuple, ast.List)):
        return len(fn.body.elts)
    return None


def _shape_dims(shape: Optional[ast.expr]) -> Optional[List[ast.expr]]:
    if isinstance(shape, (ast.Tuple, ast.List)):
        return list(shape.elts)
    return None


class _PallasSite:
    """One ``pl.pallas_call(...)`` with its grid/spec geometry resolved."""

    def __init__(self, call: ast.Call):
        self.call = call
        self.grid: Optional[ast.expr] = _kwarg(call, "grid")
        self.in_specs = _kwarg(call, "in_specs")
        self.out_specs = _kwarg(call, "out_specs")
        self.out_shape = _kwarg(call, "out_shape")
        self.scratch = _kwarg(call, "scratch_shapes")
        self.num_prefetch = 0
        self.aliases = _kwarg(call, "input_output_aliases")
        gs = _kwarg(call, "grid_spec")
        self.grid_spec_node = gs

    def absorb_grid_spec(self, spec_call: ast.Call) -> None:
        self.grid = _kwarg(spec_call, "grid") or self.grid
        self.in_specs = _kwarg(spec_call, "in_specs") or self.in_specs
        self.out_specs = _kwarg(spec_call, "out_specs") or self.out_specs
        self.scratch = _kwarg(spec_call, "scratch_shapes") or self.scratch
        np_ = _kwarg(spec_call, "num_scalar_prefetch")
        n = _int_literal(np_) if np_ is not None else None
        if n is not None:
            self.num_prefetch = n

    @property
    def grid_rank(self) -> Optional[int]:
        dims = _shape_dims(self.grid)
        return len(dims) if dims is not None else None

    @property
    def n_outputs(self) -> Optional[int]:
        shp = self.out_shape
        if isinstance(shp, (ast.List, ast.Tuple)):
            return len(shp.elts)
        if isinstance(shp, ast.BinOp) and isinstance(shp.op, ast.Mult):
            for lhs, rhs in ((shp.left, shp.right), (shp.right, shp.left)):
                n = _int_literal(rhs)
                if n is not None and isinstance(lhs, (ast.List, ast.Tuple)):
                    return len(lhs.elts) * n
        if isinstance(shp, ast.Call):
            return 1
        return None


def _module_assignments(tree: ast.Module) -> Dict[str, ast.expr]:
    """name -> last assigned value, across all scopes (simple names)."""
    out: Dict[str, ast.expr] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            out[node.targets[0].id] = node.value
    return out


def _resolve_kernel_fn(kernel_arg: ast.expr, tree: ast.Module,
                       assigns: Dict[str, ast.expr]):
    """Resolve the pallas_call kernel argument to its def, unwrapping one
    ``functools.partial(_impl, ...)`` indirection (keyword-only statics)."""
    name: Optional[str] = None
    node: Optional[ast.expr] = kernel_arg
    for _ in range(3):
        if isinstance(node, ast.Name):
            if node.id in assigns:
                node = assigns[node.id]
                continue
            name = node.id
            break
        if isinstance(node, ast.Call) and \
                tail(dotted(node.func)) == "partial" and node.args:
            node = node.args[0]
            continue
        break
    if name is None:
        return None
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                n.name == name:
            return n
    return None


# ------------------------------------------------------------ BAM301/302
def _check_pallas_calls(mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    assigns = _module_assignments(mod.tree)
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and
                tail(dotted(node.func)) == "pallas_call"):
            continue
        site = _PallasSite(node)
        gs = site.grid_spec_node
        if gs is not None:
            if isinstance(gs, ast.Name) and gs.id in assigns:
                gs = assigns[gs.id]
            if isinstance(gs, ast.Call):
                site.absorb_grid_spec(gs)
        out.extend(_check_geometry(mod, site))
        if node.args:
            kfn = _resolve_kernel_fn(node.args[0], mod.tree, assigns)
            if kfn is not None:
                out.extend(_check_input_stores(mod, site, kfn))
    return out


def _check_geometry(mod: ModuleInfo, site: _PallasSite) -> List[Finding]:
    out: List[Finding] = []
    rank = site.grid_rank
    want_arity = None if rank is None else rank + site.num_prefetch

    in_specs = _as_spec_list(site.in_specs) or []
    out_specs = _as_spec_list(site.out_specs) or []

    n_out = site.n_outputs
    if n_out is not None and out_specs and len(out_specs) != n_out:
        out.append(mod.finding(
            "BAM301", site.out_specs or site.call,
            f"out_specs has {len(out_specs)} BlockSpec(s) but out_shape "
            f"declares {n_out} output(s)"))

    out_dims = _out_shape_dims(site)
    for which, specs in (("in_specs", in_specs), ("out_specs", out_specs)):
        for idx, spec in enumerate(specs):
            shape, imap = _blockspec_parts(spec)
            if imap is not None and want_arity is not None:
                ar = _lambda_arity(imap)
                if ar is not None:
                    lo, hi, vararg = ar
                    if not vararg and not (lo <= want_arity <= hi):
                        out.append(mod.finding(
                            "BAM301", imap,
                            f"{which}[{idx}] index map takes {lo} arg(s) "
                            f"but the grid has rank {rank}"
                            + (f" + {site.num_prefetch} scalar-prefetch "
                               "operand(s)" if site.num_prefetch else "")
                            + f" = {want_arity}"))
            dims = _shape_dims(shape)
            if imap is not None and dims is not None:
                ret = _lambda_ret_len(imap)
                if ret is not None and ret != len(dims):
                    out.append(mod.finding(
                        "BAM301", spec,
                        f"{which}[{idx}] block shape has {len(dims)} "
                        f"dim(s) but its index map returns {ret} "
                        "coordinate(s)"))
            if which == "out_specs" and dims is not None and \
                    out_dims is not None and idx < len(out_dims) and \
                    out_dims[idx] is not None:
                arr = out_dims[idx]
                if len(arr) == len(dims):
                    for d, (b, a) in enumerate(zip(dims, arr)):
                        bi, ai = _int_literal(b), _int_literal(a)
                        if bi and ai and ai % bi != 0:
                            out.append(mod.finding(
                                "BAM301", b,
                                f"out_specs[{idx}] block dim {d} is {bi} "
                                f"but the output array dim is {ai} — not "
                                "divisible, the trailing block reads out "
                                "of bounds"))
    return out


def _out_shape_dims(site: _PallasSite
                    ) -> Optional[List[Optional[List[ast.expr]]]]:
    """Per-output list of dim exprs from ShapeDtypeStruct literals."""
    shp = site.out_shape

    def one(e: ast.expr) -> Optional[List[ast.expr]]:
        if isinstance(e, ast.Call) and \
                tail(dotted(e.func)) == "ShapeDtypeStruct" and e.args:
            return _shape_dims(e.args[0])
        return None

    if isinstance(shp, (ast.List, ast.Tuple)):
        return [one(e) for e in shp.elts]
    if isinstance(shp, ast.BinOp) and isinstance(shp.op, ast.Mult):
        for lhs, rhs in ((shp.left, shp.right), (shp.right, shp.left)):
            n = _int_literal(rhs)
            if n is not None and isinstance(lhs, (ast.List, ast.Tuple)):
                return [one(e) for e in lhs.elts] * n
    if isinstance(shp, ast.Call):
        return [one(shp)]
    return None


def _aliased_input_indices(site: _PallasSite) -> Optional[set]:
    """Input indices named in a literal input_output_aliases dict, or
    ``None`` when the kwarg exists but is not a literal (→ skip checks)."""
    al = site.aliases
    if al is None:
        return set()
    if isinstance(al, ast.Dict):
        idxs = set()
        for k in al.keys:
            i = _int_literal(k) if k is not None else None
            if i is None:
                return None
            idxs.add(i)
        return idxs
    return None


def _check_input_stores(mod: ModuleInfo, site: _PallasSite,
                        kfn) -> List[Finding]:
    n_in = len(_as_spec_list(site.in_specs) or [])
    if not n_in:
        return []
    aliased = _aliased_input_indices(site)
    if aliased is None:
        return []
    params = [a.arg for a in kfn.args.posonlyargs + kfn.args.args]
    k = site.num_prefetch
    input_params = {}
    for i, p in enumerate(params[k:k + n_in]):
        if i not in aliased:
            input_params[p] = i
    if not input_params:
        return []
    out: List[Finding] = []
    for node in ast.walk(kfn):
        tgt = None
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            tgts = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in tgts:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.value, ast.Name) and \
                        t.value.id in input_params:
                    tgt = t
        if tgt is not None:
            out.append(mod.finding(
                "BAM302", tgt,
                f"kernel stores into input ref `{tgt.value.id}` "
                "(input index "
                f"{input_params[tgt.value.id]}) with no matching "
                "input_output_aliases entry — mutating a "
                "possibly-donated input buffer"))
    return out


# ----------------------------------------------------------------- BAM303
DTYPE_DEFAULT_FLOAT = {"zeros", "ones", "empty"}
EXEMPT_LIKE = {"zeros_like", "ones_like", "full_like", "empty_like"}


def _has_float_literal(node: ast.expr) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, float):
            return True
    return False


def _check_f64(mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = dotted(node.func)
        t = tail(fname)
        if not fname.startswith(("jnp.", "jax.numpy.")):
            continue
        if t in EXEMPT_LIKE:
            continue
        has_dtype = _kwarg(node, "dtype") is not None
        if t in DTYPE_DEFAULT_FLOAT:
            if not has_dtype and len(node.args) < 2:
                out.append(mod.finding(
                    "BAM303", node,
                    f"`jnp.{t}` without an explicit dtype defaults to "
                    "the x64-dependent float dtype — float64 under "
                    "jax_enable_x64, which breaks TPU lowering and "
                    "doubles VMEM; pass dtype= explicitly"))
        elif t == "full":
            fill = node.args[1] if len(node.args) >= 2 else \
                _kwarg(node, "fill_value")
            if not has_dtype and len(node.args) < 3 and \
                    fill is not None and _has_float_literal(fill):
                out.append(mod.finding(
                    "BAM303", node,
                    "`jnp.full` with a float fill and no dtype "
                    "promotes to float64 under jax_enable_x64; pass "
                    "dtype= explicitly"))
        elif t in ("arange", "linspace", "array"):
            if not has_dtype and \
                    any(_has_float_literal(a) for a in node.args):
                out.append(mod.finding(
                    "BAM303", node,
                    f"`jnp.{t}` with float literal(s) and no dtype "
                    "promotes to float64 under jax_enable_x64; pass "
                    "dtype= explicitly"))
    return out
