"""Pass 4 — IOMetrics conservation: every counter classified and surfaced.

The multi-tenant facade depends on a complete additive-vs-watermark split
of ``IOMetrics``: per-op deltas subtract additive counters and carry
watermarks, accumulation sums additive counters and maxes watermarks.  A
field added to the dataclass but missed in the classification tuples, in
``zeros()``, or in ``summary()`` silently breaks metrics conservation —
the differential oracle sums tenant deltas that no longer reconcile with
the global counters, or a counter exists that no benchmark can observe.

Rules
-----
BAM401  classification mismatch: a name in ``WATERMARK_FIELDS`` /
        ``ADDITIVE_FIELDS`` that is not a declared field, a field in
        neither (when both are literal), or a field in both.
BAM402  a declared field that never appears in ``summary()`` — the
        counter is collected but unobservable.
BAM403  a declared field not initialized by keyword in the
        ``IOMetrics(...)`` constructor call inside ``zeros()``.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from tools.bamlint.core import Finding, ModuleInfo
from tools.bamlint.reach import dotted, tail

RULES = {
    "BAM401": "IOMetrics field classification mismatch "
              "(additive vs watermark)",
    "BAM402": "IOMetrics field missing from summary()",
    "BAM403": "IOMetrics field not initialized in zeros()",
}

METRICS_CLASS = "IOMetrics"


def _find_class(tree: ast.Module) -> Optional[ast.ClassDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == METRICS_CLASS:
            return node
    return None


def _declared_fields(cls: ast.ClassDef) -> List[ast.AnnAssign]:
    out = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and \
                isinstance(stmt.target, ast.Name):
            out.append(stmt)
    return out


def _literal_names(node: ast.expr) -> Optional[List[str]]:
    """Element strings of a literal tuple/list of str constants."""
    if isinstance(node, (ast.Tuple, ast.List)):
        names = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                names.append(e.value)
            else:
                return None
        return names
    return None


def _method(cls: ast.ClassDef, name: str):
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                stmt.name == name:
            return stmt
    return None


def check(mod: ModuleInfo) -> List[Finding]:
    cls = _find_class(mod.tree)
    if cls is None:
        return []
    out: List[Finding] = []
    field_nodes = _declared_fields(cls)
    fields = [f.target.id for f in field_nodes]
    field_set = set(fields)

    # ------------------------------------------------ BAM401 classification
    watermark: Optional[List[str]] = None
    additive: Optional[List[str]] = None
    wm_node = add_node = None
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if name == "WATERMARK_FIELDS":
                watermark, wm_node = _literal_names(node.value), node
            elif name == "ADDITIVE_FIELDS":
                additive, add_node = _literal_names(node.value), node

    if wm_node is None:
        out.append(mod.finding(
            "BAM401", cls,
            "module defines IOMetrics but no WATERMARK_FIELDS "
            "classification — delta/accumulate cannot distinguish "
            "additive counters from high-watermarks"))
    if watermark is not None:
        for name in watermark:
            if name not in field_set:
                out.append(mod.finding(
                    "BAM401", wm_node,
                    f"WATERMARK_FIELDS names `{name}`, which is not a "
                    "declared IOMetrics field"))
    if additive is not None:
        for name in additive:
            if name not in field_set:
                out.append(mod.finding(
                    "BAM401", add_node,
                    f"ADDITIVE_FIELDS names `{name}`, which is not a "
                    "declared IOMetrics field"))
        if watermark is not None:
            both = set(additive) & set(watermark)
            for name in sorted(both):
                out.append(mod.finding(
                    "BAM401", add_node,
                    f"field `{name}` is classified both additive and "
                    "watermark — accumulate would double-count it"))
            missing = field_set - set(additive) - set(watermark)
            for name in sorted(missing):
                out.append(mod.finding(
                    "BAM401", add_node,
                    f"field `{name}` is in neither ADDITIVE_FIELDS nor "
                    "WATERMARK_FIELDS — it is dropped by "
                    "delta/accumulate and conservation breaks"))
    # ADDITIVE_FIELDS derived generically (e.g. a comprehension over
    # __dataclass_fields__ minus WATERMARK_FIELDS) is complete by
    # construction — nothing to check beyond the watermark names above.

    # ----------------------------------------------------- BAM402 summary
    summ = _method(cls, "summary")
    if summ is None:
        out.append(mod.finding(
            "BAM402", cls,
            "IOMetrics has no summary() — counters are collected but "
            "unobservable"))
    else:
        seen = _referenced_fields(summ, field_set)
        for f in field_nodes:
            if f.target.id not in seen:
                out.append(mod.finding(
                    "BAM402", f,
                    f"field `{f.target.id}` never appears in summary() "
                    "— the counter is collected but unobservable"))

    # ------------------------------------------------------- BAM403 zeros
    zeros = _method(cls, "zeros")
    if zeros is None:
        out.append(mod.finding(
            "BAM403", cls,
            "IOMetrics has no zeros() constructor — there is no "
            "canonical all-zero state to delta against"))
    else:
        init = _constructor_keywords(zeros)
        if init is not None:
            for f in field_nodes:
                if f.target.id not in init:
                    out.append(mod.finding(
                        "BAM403", f,
                        f"field `{f.target.id}` is not initialized by "
                        "keyword in the IOMetrics(...) call inside "
                        "zeros() — construction raises (or worse, a "
                        "default hides a missing counter)"))
    return out


def _referenced_fields(fn, field_set: Set[str]) -> Set[str]:
    """Fields mentioned in ``fn`` as string keys or ``self.<field>``."""
    seen: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Constant) and isinstance(node.value, str) \
                and node.value in field_set:
            seen.add(node.value)
        elif isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self" and node.attr in field_set:
            seen.add(node.attr)
    return seen


def _constructor_keywords(fn) -> Optional[Set[str]]:
    """Keyword names of the ``IOMetrics(...)`` call in ``fn``; ``None``
    when the call uses ``**kwargs`` (not statically checkable)."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and \
                tail(dotted(node.func)) == METRICS_CLASS:
            names: Set[str] = set()
            for kw in node.keywords:
                if kw.arg is None:        # **kw splat
                    return None
                names.add(kw.arg)
            return names
    return None
