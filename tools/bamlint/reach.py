"""Jit-reachability + taint machinery shared by the bamlint passes.

``analyze(module)`` classifies every function/lambda in a module:

* **jit root** — directly traced: decorated with ``jax.jit`` (bare or via
  ``functools.partial``), passed to ``jax.jit(...)`` / the repo's
  ``_jit_op``/``_cached_jit`` op-family caches, passed to a traced
  higher-order primitive (``lax.scan``/``cond``/``while_loop``/...), or a
  function whose signature takes a traced-typed parameter (``jax.Array``,
  ``BamState``, ``CacheState``, ... — the repo's functional-core calling
  convention).
* **kernel** — the function handed (directly or through
  ``functools.partial``) to ``pl.pallas_call`` as its kernel body.
* **reachable** — transitively callable (by simple name, intra-module)
  from a root or kernel.

Host callbacks stay invisible: functions passed to ``pure_callback`` /
``io_callback`` are *not* marked reachable through that edge.

Taint is per-function and intentionally conservative-positive: a value is
*tainted* (tracer-derived) only on positive evidence — a traced-typed or
root-function parameter, a ``jnp.``/``jax.lax.`` result, or arithmetic /
indexing / unknown calls over tainted inputs.  Attribute access through a
known-static attribute (``.shape``, ``.dtype``, ``.kind``, ...) launders
the taint, as do ``len()``/``range()``-of-untainted and host-transfer
calls themselves.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# Annotations that mark a parameter as carrying traced values (the repo's
# pytree state types plus the jax array types).
TRACED_TYPES = (
    "jax.Array", "jnp.ndarray", "jax.numpy.ndarray", "ArrayLike",
    "BamState", "RuntimeState", "CacheState", "QueueState",
    "IOToken", "IORequest", "IOMetrics", "Completions", "ProbeResult",
    "AllocResult", "SubmitReceipt", "HBMStorage",
)

# Attribute reads that yield static (trace-time Python) values even on a
# traced object: pytree metadata, shape/dtype introspection, the .at
# updater (its result is traced again via the call chain on the update).
STATIC_ATTRS = {
    "shape", "dtype", "ndim", "size", "itemsize", "kind", "at",
    "n_devices", "stripe_blocks", "num_lines", "block_elems",
    "ways", "num_sets", "n_tenants", "num_queues", "depth", "group_size",
}

# Higher-order traced primitives: function-valued arguments become
# jit-reachable with traced parameters.
TRACED_HOFS = {"scan", "cond", "while_loop", "fori_loop", "switch",
               "vmap", "pmap", "grad", "value_and_grad", "checkpoint",
               "remat", "custom_vjp", "custom_jvp", "associative_scan",
               "map"}
# ... while these receive *host* functions.
HOST_HOFS = {"pure_callback", "io_callback", "debug_callback"}

JIT_CACHE_FNS = {"_jit_op", "_cached_jit"}


def dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression ('jax.jit', 'pl.pallas_call')."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Call):
        return dotted(node.func)
    return ""


def tail(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _is_jit_name(name: str) -> bool:
    return tail(name) == "jit"


def _is_pallas_call(name: str) -> bool:
    return tail(name) == "pallas_call"


@dataclasses.dataclass
class FuncInfo:
    node: ast.AST                       # FunctionDef | AsyncFunctionDef | Lambda
    name: str                           # "" for lambdas
    parent: Optional["FuncInfo"]        # enclosing function, if any
    is_root: bool = False               # directly traced entry point
    is_kernel: bool = False             # pallas_call kernel body
    reachable: bool = False
    kernel_reachable: bool = False


class ModuleAnalysis:
    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.funcs: Dict[ast.AST, FuncInfo] = {}
        self.by_name: Dict[str, List[FuncInfo]] = {}
        self._index(tree)
        self._mark_roots()
        self._propagate()

    # ------------------------------------------------------------ indexing
    def _index(self, tree: ast.Module) -> None:
        analysis = self

        class Indexer(ast.NodeVisitor):
            def __init__(self) -> None:
                self.stack: List[FuncInfo] = []

            def _add(self, node: ast.AST, name: str) -> None:
                parent = self.stack[-1] if self.stack else None
                fi = FuncInfo(node=node, name=name, parent=parent)
                analysis.funcs[node] = fi
                if name:
                    analysis.by_name.setdefault(name, []).append(fi)
                self.stack.append(fi)
                for child in ast.iter_child_nodes(node):
                    self.visit(child)
                self.stack.pop()

            def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
                self._add(node, node.name)

            def visit_AsyncFunctionDef(self, node) -> None:
                self._add(node, node.name)

            def visit_Lambda(self, node: ast.Lambda) -> None:
                self._add(node, "")

        Indexer().visit(tree)

    # --------------------------------------------------------------- roots
    def _func_args(self, call: ast.Call) -> List[ast.AST]:
        """Function-valued argument expressions of a call, unwrapping
        ``functools.partial(f, ...)``."""
        out: List[ast.AST] = []
        for a in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(a, FuncNode):
                out.append(a)
            elif isinstance(a, ast.Name):
                out.append(a)
            elif isinstance(a, ast.Call) and tail(dotted(a.func)) == "partial":
                out.extend(self._func_args(a))
        return out

    def _resolve(self, expr: ast.AST) -> List[FuncInfo]:
        if isinstance(expr, FuncNode):
            fi = self.funcs.get(expr)
            return [fi] if fi else []
        if isinstance(expr, ast.Name):
            return self.by_name.get(expr.id, [])
        if isinstance(expr, ast.Attribute):
            return self.by_name.get(expr.attr, [])
        return []

    def _mark_roots(self) -> None:
        # (a) decorators
        for fi in self.funcs.values():
            node = fi.node
            for dec in getattr(node, "decorator_list", []):
                name = dotted(dec)
                if _is_jit_name(name):
                    fi.is_root = True
                if isinstance(dec, ast.Call):
                    inner = dotted(dec)
                    if tail(inner) == "partial" and any(
                            _is_jit_name(dotted(a)) for a in dec.args):
                        fi.is_root = True

        # (b) annotation-based traced surface
        for fi in self.funcs.values():
            node = fi.node
            args = getattr(node, "args", None)
            if args is None:
                continue
            for a in (args.posonlyargs + args.args + args.kwonlyargs):
                ann = getattr(a, "annotation", None)
                if ann is not None and self._is_traced_ann(ann):
                    fi.is_root = True
                    break

        # (c) call-site roots: jax.jit(f), pallas_call(kernel),
        #     _jit_op(key, make), traced HOFs
        for call in ast.walk(self.tree):
            if not isinstance(call, ast.Call):
                continue
            fname = dotted(call.func)
            t = tail(fname)
            if _is_jit_name(fname) or t in JIT_CACHE_FNS or t in TRACED_HOFS:
                if t in HOST_HOFS:
                    continue
                for arg in self._func_args(call):
                    for fi in self._resolve(arg):
                        fi.is_root = True
            if _is_pallas_call(fname) and call.args:
                for fi in self._resolve(call.args[0]):
                    fi.is_kernel = True
                # kernel may arrive via functools.partial(kernel, ...)
                a0 = call.args[0]
                if isinstance(a0, ast.Call) and \
                        tail(dotted(a0.func)) == "partial":
                    for arg in self._func_args(a0):
                        for fi in self._resolve(arg):
                            fi.is_kernel = True
                if isinstance(a0, ast.Name):
                    # kernel = functools.partial(_impl, ...) earlier
                    for assign in ast.walk(self.tree):
                        if isinstance(assign, ast.Assign) and \
                                isinstance(assign.value, ast.Call) and \
                                tail(dotted(assign.value.func)) == "partial":
                            for tgt in assign.targets:
                                if isinstance(tgt, ast.Name) and \
                                        tgt.id == a0.id:
                                    for arg in self._func_args(assign.value):
                                        for fi in self._resolve(arg):
                                            fi.is_kernel = True

    def _is_traced_ann(self, ann: ast.AST) -> bool:
        try:
            text = ast.unparse(ann)
        except Exception:
            return False
        return any(t in text for t in TRACED_TYPES)

    # ---------------------------------------------------------- propagation
    def _propagate(self) -> None:
        work: List[FuncInfo] = []
        for fi in self.funcs.values():
            if fi.is_root or fi.is_kernel:
                fi.reachable = True
                fi.kernel_reachable = fi.is_kernel
                work.append(fi)
        while work:
            fi = work.pop()
            body = fi.node.body
            stmts = body if isinstance(body, list) else [body]
            for stmt in stmts:
                for node in ast.walk(stmt):
                    callees: List[FuncInfo] = []
                    if isinstance(node, ast.Call):
                        t = tail(dotted(node.func))
                        if t in HOST_HOFS:
                            continue
                        callees = self._resolve(node.func)
                    elif isinstance(node, FuncNode) and node is not fi.node:
                        sub = self.funcs.get(node)
                        if sub is not None and sub.parent is fi:
                            callees = [sub]
                    for callee in callees:
                        changed = False
                        if not callee.reachable:
                            callee.reachable = True
                            changed = True
                        if fi.kernel_reachable and \
                                not callee.kernel_reachable:
                            callee.kernel_reachable = True
                            changed = True
                        if changed:
                            work.append(callee)

    # ------------------------------------------------------------- queries
    def reachable_functions(self) -> List[FuncInfo]:
        return [fi for fi in self.funcs.values() if fi.reachable]

    def kernels(self) -> List[FuncInfo]:
        return [fi for fi in self.funcs.values() if fi.kernel_reachable]


# ------------------------------------------------------------------- taint
def seed_taint(fi: FuncInfo) -> Set[str]:
    """Parameter names considered tracer-carrying for this function."""
    tainted: Set[str] = set()
    args = getattr(fi.node, "args", None)
    if args is None:
        return tainted
    direct = fi.is_root or fi.is_kernel
    params = args.posonlyargs + args.args + args.kwonlyargs
    for a in params:
        if a.arg in ("self", "cls"):
            continue
        ann = getattr(a, "annotation", None)
        if ann is not None:
            # Positive evidence only: an annotated parameter is traced
            # iff its annotation names a traced type.  Config dataclasses
            # (`ArchConfig`), `int | None` knobs, paths etc. are static.
            text = ""
            try:
                text = ast.unparse(ann)
            except Exception:
                pass
            if any(t in text for t in TRACED_TYPES):
                tainted.add(a.arg)
        elif direct:
            tainted.add(a.arg)
    return tainted


class TaintTracker:
    """Forward may-taint propagation over one function body (two sweeps to
    pick up loop-carried names)."""

    def __init__(self, fi: FuncInfo):
        self.fi = fi
        self.tainted: Set[str] = seed_taint(fi)
        body = fi.node.body
        self.stmts = body if isinstance(body, list) else []
        for _ in range(2):
            for stmt in self.stmts:
                self._sweep(stmt)

    # -- expression taint -------------------------------------------------
    def expr_tainted(self, e: ast.AST) -> bool:
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in STATIC_ATTRS:
                return False
            return self.expr_tainted(e.value)
        if isinstance(e, ast.Subscript):
            return self.expr_tainted(e.value) or self.expr_tainted(e.slice)
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            return any(self.expr_tainted(x) for x in e.elts)
        if isinstance(e, ast.BinOp):
            return self.expr_tainted(e.left) or self.expr_tainted(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.expr_tainted(e.operand)
        if isinstance(e, ast.BoolOp):
            return any(self.expr_tainted(v) for v in e.values)
        if isinstance(e, ast.Compare):
            # `x is None` / `"key" in d` style checks are structural —
            # identity and container membership, not value comparisons.
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in e.ops):
                return False
            return self.expr_tainted(e.left) or \
                any(self.expr_tainted(c) for c in e.comparators)
        if isinstance(e, ast.IfExp):
            return any(self.expr_tainted(x)
                       for x in (e.test, e.body, e.orelse))
        if isinstance(e, ast.Call):
            return self.call_tainted(e)
        if isinstance(e, ast.Starred):
            return self.expr_tainted(e.value)
        if isinstance(e, ast.Slice):
            return any(x is not None and self.expr_tainted(x)
                       for x in (e.lower, e.upper, e.step))
        return False

    def call_tainted(self, call: ast.Call) -> bool:
        fname = dotted(call.func)
        t = tail(fname)
        head = fname.split(".", 1)[0]
        # jnp./lax. producers are traced by construction
        if head in ("jnp", "lax") or ".lax." in fname or \
                fname.startswith("jax.lax") or head == "jax.numpy" or \
                fname.startswith("jnp.") or fname.startswith("jax.numpy"):
            return True
        # host transfers & static introspection launder taint
        if t in ("len", "isinstance", "hash", "id", "repr", "print",
                 "device_get", "list", "tuple", "sorted", "set", "dict",
                 "frozenset"):
            return False
        if t in ("float", "int", "bool", "str"):
            return False               # host scalars (flagged separately)
        if head == "np" or head == "numpy":
            return False
        if t == "range":
            return any(self.expr_tainted(a) for a in call.args)
        # method call on a traced object stays traced (e.g. x.sum())
        if isinstance(call.func, ast.Attribute) and \
                self.expr_tainted(call.func):
            return True
        # unknown call: traced if any argument is
        return any(self.expr_tainted(a) for a in call.args) or \
            any(self.expr_tainted(kw.value) for kw in call.keywords)

    # -- statement sweep --------------------------------------------------
    def _bind(self, target: ast.AST, value_tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if value_tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, value_tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, value_tainted)
        # subscript/attribute stores don't (re)bind local names

    def _sweep(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            # element-wise for `a, b = x, y` so laundering attributes
            # (`kind, v = t.kind, t.value`) don't cross-contaminate
            if len(stmt.targets) == 1 and \
                    isinstance(stmt.targets[0], (ast.Tuple, ast.List)) and \
                    isinstance(stmt.value, (ast.Tuple, ast.List)) and \
                    len(stmt.targets[0].elts) == len(stmt.value.elts):
                for tgt, val in zip(stmt.targets[0].elts,
                                    stmt.value.elts):
                    self._bind(tgt, self.expr_tainted(val))
                return
            vt = self.expr_tainted(stmt.value)
            for tgt in stmt.targets:
                self._bind(tgt, vt)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._bind(stmt.target, self.expr_tainted(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            if self.expr_tainted(stmt.value) or \
                    self.expr_tainted(stmt.target):
                self._bind(stmt.target, True)
        elif isinstance(stmt, ast.For):
            self._bind(stmt.target, self.expr_tainted(stmt.iter))
            for s in stmt.body + stmt.orelse:
                self._sweep(s)
        elif isinstance(stmt, (ast.While, ast.If)):
            for s in stmt.body + stmt.orelse:
                self._sweep(s)
        elif isinstance(stmt, ast.With):
            for s in stmt.body:
                self._sweep(s)
        elif isinstance(stmt, ast.Try):
            for s in stmt.body + stmt.orelse + stmt.finalbody:
                self._sweep(s)
            for h in stmt.handlers:
                for s in h.body:
                    self._sweep(s)

    # -- traversal helper: statements of THIS function only ---------------
    def walk_own(self):
        """Yield every AST node belonging to this function, skipping the
        bodies of nested function definitions/lambdas."""
        stack: List[ast.AST] = list(self.stmts)
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, FuncNode):
                continue           # nested def/lambda: don't descend
            for child in ast.iter_child_nodes(node):
                if isinstance(child, FuncNode):
                    continue
                stack.append(child)
