"""Pass 6 — receipt visibility: drop/error accounting must be readable.

The queue layer reports back-pressure and fault accounting *only* through
its return values: ``enqueue``/``enqueue_segments`` return a
``SubmitReceipt`` (``accepted`` mask, per-device drop counts, command
tickets) and ``drain_accounting`` returns a ``DrainReceipt`` (completed /
errored / retried commands per device).  A call site that throws the
receipt away cannot tell a served wavefront from one the rings silently
dropped or the fault model failed — exactly the blindness the robustness
PR removed.  The fix is one binding: read the receipt (or at least its
``accepted``/error fields), or carry it into the token like
``BamArray.submit`` does.

Deliberate discards suppress with ``# bamlint: ignore[BAM108]`` and a
justification.

Rules
-----
BAM108  a ``SubmitReceipt``/``DrainReceipt``-returning call whose receipt
        is provably discarded: the bare-statement form ``Q.enqueue(...)``,
        the underscore form ``qs, _ = Q.enqueue(...)``, and the
        subscript form ``qs = Q.enqueue(...)[0]``.
"""
from __future__ import annotations

import ast
from typing import List

from tools.bamlint.core import Finding, ModuleInfo
from tools.bamlint.reach import dotted, tail

RULES = {
    "BAM108": "SubmitReceipt/DrainReceipt discarded: drop/error "
              "accounting is unreadable at this call site",
}

# Calls returning ``(queue_state, receipt)`` (or ``(qs, [receipts])``).
RECEIPT_TAILS = ("enqueue", "enqueue_segments", "drain_accounting")


def _is_receipt_call(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and \
        tail(dotted(node.func)) in RECEIPT_TAILS


def _is_discard_name(node: ast.expr) -> bool:
    return isinstance(node, ast.Name) and node.id.lstrip("_") == ""


def check(mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        # bare statement: the whole (qs, receipt) result vanishes
        if isinstance(node, ast.Expr) and _is_receipt_call(node.value):
            call = node.value
            out.append(mod.finding(
                "BAM108", node,
                f"result of {dotted(call.func)}(...) discarded — the "
                f"receipt carries the drop/error accounting"))
            continue
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        value = node.value
        targets = node.targets if isinstance(node, ast.Assign) \
            else ([node.target] if node.value is not None else [])
        # subscript form: ``qs = Q.enqueue(...)[0]`` peels the state and
        # drops the receipt in the same expression
        if isinstance(value, ast.Subscript) and \
                _is_receipt_call(value.value):
            idx = value.slice
            if isinstance(idx, ast.Constant) and idx.value == 0:
                out.append(mod.finding(
                    "BAM108", node,
                    f"[0]-subscript keeps only the state from "
                    f"{dotted(value.value.func)}(...) — the receipt "
                    f"is dropped unread"))
            continue
        if not _is_receipt_call(value):
            continue
        # underscore form: ``qs, _ = Q.enqueue(...)``
        for tgt in targets:
            if isinstance(tgt, (ast.Tuple, ast.List)) and \
                    len(tgt.elts) >= 2 and \
                    all(_is_discard_name(e) for e in tgt.elts[1:]):
                out.append(mod.finding(
                    "BAM108", node,
                    f"receipt from {dotted(value.func)}(...) bound to "
                    f"'_' and never read"))
                break
    return out
