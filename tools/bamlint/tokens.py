"""Pass 2 — IOToken lifecycle (linear-type discipline) + pin pairing.

An :class:`IOToken` must flow from its ``submit`` to **exactly one**
``wait``: a dropped token leaks the cache pins taken at submit (refcounts
never return to zero, the lines become unevictable); a double-waited token
over-releases them (refcount underflow corrupts the clock sweep).  The
same linearity governs ``acquire``/``release`` pin pairs inside the state
machinery itself.

The analysis is per-function and deliberately conservative: a token that
*escapes* (returned, yielded, appended to a container, stored into a
structure, or passed to another function) is treated as consumed — its
lifecycle continues in the consumer.  Findings therefore mean "this
binding provably never flows anywhere" (leak) or "this binding is waited
twice on one path" (double wait).

Rules
-----
BAM201  token leak: a ``submit``/``lookup_submit`` result bound to a name
        that is never waited, returned, stored, or passed on.
BAM202  double wait: the same token binding waited more than once on a
        single path (including once-per-iteration waits on a token bound
        outside the loop).
BAM203  unpaired pin: a function that calls ``acquire`` (taking cache
        pins) without releasing them, returning them, or binding them
        into an :class:`IOToken` for the waiter to release.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.bamlint.core import Finding, ModuleInfo
from tools.bamlint.reach import FuncNode, dotted, tail

RULES = {
    "BAM201": "IOToken leaked: submit result never waited or passed on",
    "BAM202": "IOToken waited more than once on a single path",
    "BAM203": "cache pins acquired without release / IOToken hand-off",
}

SUBMIT_TAILS = ("submit", "lookup_submit")
WAIT_TAILS = ("wait", "lookup_wait")


def _functions(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _own_statements(fn) -> List[ast.stmt]:
    return list(fn.body)


def _walk_own(fn):
    """All nodes of ``fn`` excluding nested function bodies."""
    stack: List[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, FuncNode):
            continue               # nested def/lambda: don't descend
        for child in ast.iter_child_nodes(node):
            if isinstance(child, FuncNode):
                continue
            stack.append(child)


def _call_tail(call: ast.Call) -> str:
    return tail(dotted(call.func))


def _is_submit_call(call: ast.Call, aliases: Set[str]) -> bool:
    t = _call_tail(call)
    if t in SUBMIT_TAILS or t.startswith("submit") or \
            t.endswith("_submit"):
        return True
    if isinstance(call.func, ast.Name) and call.func.id in aliases:
        return True
    # submit_jit()(...) inline
    if isinstance(call.func, ast.Call) and \
            _call_tail(call.func).endswith("submit_jit"):
        return True
    return False


def _is_wait_call(call: ast.Call, aliases: Set[str]) -> bool:
    t = _call_tail(call)
    if t in WAIT_TAILS or t.endswith("_wait") or t.startswith("wait"):
        return True
    if isinstance(call.func, ast.Name) and call.func.id in aliases:
        return True
    if isinstance(call.func, ast.Call) and \
            _call_tail(call.func).endswith("wait_jit"):
        return True
    return False


def _source_aliases(fn, needle: str) -> Set[str]:
    """Local names bound to a submit/wait callable (``submit =
    jax.jit(lambda ...: arr.submit(...))``, ``wait = arr.wait_jit()``)."""
    out: Set[str] = set()
    for node in _walk_own(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            try:
                rhs = ast.unparse(node.value)
            except Exception:
                continue
            if needle in rhs:
                out.add(node.targets[0].id)
    return out


class _Event:
    __slots__ = ("kind", "node", "loops", "branch")

    def __init__(self, kind: str, node: ast.AST,
                 loops: Tuple[ast.AST, ...], branch: Tuple[ast.AST, ...]):
        self.kind = kind          # "bind" | "rebind" | "wait" | "escape"
        self.node = node
        self.loops = loops        # enclosing loop nodes, outermost first
        self.branch = branch      # (If-node, "body"/"orelse") chain


def _collect_events(fn, name: str, submit_aliases: Set[str],
                    wait_aliases: Set[str]) -> List[_Event]:
    """Linear (source-ordered) bind/use events for one local name."""
    events: List[_Event] = []

    def rec(stmts, loops, branch):
        for stmt in stmts:
            _stmt_events(stmt, loops, branch)

    def _expr_uses(expr, loops, branch, in_wait_call=False):
        """Register Load-uses of `name` inside an expression."""
        for node in ast.walk(expr):
            if isinstance(node, FuncNode):
                continue
            if isinstance(node, ast.Call):
                is_wait = _is_wait_call(node, wait_aliases)
                for sub in list(node.args) + \
                        [kw.value for kw in node.keywords]:
                    for n2 in ast.walk(sub):
                        if isinstance(n2, ast.Name) and n2.id == name and \
                                isinstance(n2.ctx, ast.Load):
                            events.append(_Event(
                                "wait" if is_wait else "escape",
                                node, loops, branch))
        # bare loads outside calls (return tok, tuples, comparisons...)
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id == name and \
                    isinstance(node.ctx, ast.Load):
                if not any(node in ast.walk(c) for c in _calls_in(expr)):
                    events.append(_Event("escape", node, loops, branch))

    def _calls_in(expr):
        return [n for n in ast.walk(expr) if isinstance(n, ast.Call)]

    def _binds_name(target) -> Optional[str]:
        if isinstance(target, ast.Name) and target.id == name:
            return "plain"
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                if _binds_name(elt):
                    return "plain"
        return None

    def _stmt_events(stmt, loops, branch):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, ast.Assign):
            _expr_uses(stmt.value, loops, branch)
            for tgt in stmt.targets:
                if _binds_name(tgt):
                    is_token = isinstance(stmt.value, ast.Call) and \
                        _is_submit_call(stmt.value, submit_aliases) and \
                        isinstance(tgt, (ast.Tuple, ast.List))
                    events.append(_Event(
                        "bind" if is_token else "rebind",
                        stmt, loops, branch))
        elif isinstance(stmt, ast.AugAssign):
            _expr_uses(stmt.value, loops, branch)
        elif isinstance(stmt, ast.Expr):
            _expr_uses(stmt.value, loops, branch)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                _expr_uses(stmt.value, loops, branch)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            _expr_uses(stmt.iter, loops, branch)
            if _binds_name(stmt.target):
                events.append(_Event("rebind", stmt, loops, branch))
            rec(stmt.body, loops + (stmt,), branch)
            rec(stmt.orelse, loops, branch)
        elif isinstance(stmt, ast.While):
            _expr_uses(stmt.test, loops, branch)
            rec(stmt.body, loops + (stmt,), branch)
            rec(stmt.orelse, loops, branch)
        elif isinstance(stmt, ast.If):
            _expr_uses(stmt.test, loops, branch)
            rec(stmt.body, loops, branch + ((stmt, "body"),))
            rec(stmt.orelse, loops, branch + ((stmt, "orelse"),))
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                _expr_uses(item.context_expr, loops, branch)
            rec(stmt.body, loops, branch)
        elif isinstance(stmt, ast.Try):
            rec(stmt.body, loops, branch)
            for h in stmt.handlers:
                rec(h.body, loops, branch)
            rec(stmt.orelse, loops, branch)
            rec(stmt.finalbody, loops, branch)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    _expr_uses(child, loops, branch)

    rec(_own_statements(fn), (), ())
    # Within one statement the RHS is evaluated before the target binds
    # (`st, tok = step(st, tok)`), so uses order before (re)binds on the
    # same line.
    events.sort(key=lambda e: (getattr(e.node, "lineno", 0),
                               0 if e.kind in ("wait", "escape") else 1,
                               getattr(e.node, "col_offset", 0)))
    return events


def _sibling_branches(a: _Event, b: _Event) -> bool:
    """True when a and b live in mutually exclusive branches of one If."""
    for (ifa, sidea) in a.branch:
        for (ifb, sideb) in b.branch:
            if ifa is ifb and sidea != sideb:
                return True
    return False


def check(mod: ModuleInfo) -> List[Finding]:
    out: List[Finding] = []
    for fn in _functions(mod.tree):
        out.extend(_check_tokens(mod, fn))
        out.extend(_check_pins(mod, fn))
    return out


def _token_names(fn, submit_aliases: Set[str]) -> Set[str]:
    """Names bound from the non-state half of a submit tuple unpack."""
    names: Set[str] = set()
    for node in _walk_own(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], (ast.Tuple, ast.List)) and \
                isinstance(node.value, ast.Call) and \
                _is_submit_call(node.value, submit_aliases):
            elts = node.targets[0].elts
            # (state, token) or (state, token, extra...) convention
            if len(elts) >= 2 and isinstance(elts[1], ast.Name):
                names.add(elts[1].id)
    return names


def _check_tokens(mod: ModuleInfo, fn) -> List[Finding]:
    out: List[Finding] = []
    submit_aliases = _source_aliases(fn, "submit")
    wait_aliases = _source_aliases(fn, "wait")
    for name in sorted(_token_names(fn, submit_aliases)):
        events = _collect_events(fn, name, submit_aliases, wait_aliases)
        n = len(events)
        for i, ev in enumerate(events):
            if ev.kind != "bind":
                continue
            # uses attributable to this binding: everything up to the next
            # (re)bind — plus, for a binding inside a loop, earlier events
            # in the same loop body (the back edge), unless an earlier
            # (re)bind in that loop body intercepts them.
            uses: List[_Event] = []
            for j in range(i + 1, n):
                if events[j].kind in ("bind", "rebind"):
                    break
                uses.append(events[j])
            else:
                j = n
            if ev.loops:
                loop = ev.loops[-1]
                back = [e for e in events[:i]
                        if loop in e.loops and
                        e.kind not in ("bind", "rebind")]
                intercepted = any(e.kind in ("bind", "rebind")
                                  for e in events[:i] if loop in e.loops)
                if not intercepted:
                    uses.extend(back)
            if not uses:
                out.append(mod.finding(
                    "BAM201", ev.node,
                    f"token `{name}` from this submit is never waited, "
                    "returned, or passed on — its cache pins leak "
                    "(refcounts never return to zero)"))
                continue
            waits = [u for u in uses if u.kind == "wait"]
            # once-per-iteration wait on a token bound outside the loop
            for w in waits:
                if len(w.loops) > len(ev.loops) and \
                        not any(e.kind in ("bind", "rebind")
                                and w.loops[-1] in e.loops
                                for e in events):
                    out.append(mod.finding(
                        "BAM202", w.node,
                        f"token `{name}` is waited inside a loop but "
                        "bound outside it: every iteration after the "
                        "first re-waits the same token and over-releases "
                        "its pins"))
                    break
            else:
                # two waits on one path (not in sibling if/else branches)
                for a in range(len(waits)):
                    for b in range(a + 1, len(waits)):
                        if not _sibling_branches(waits[a], waits[b]):
                            out.append(mod.finding(
                                "BAM202", waits[b].node,
                                f"token `{name}` is waited twice on one "
                                "path — the second wait over-releases "
                                "its cache pins"))
                            break
                    else:
                        continue
                    break
    return out


def _check_pins(mod: ModuleInfo, fn) -> List[Finding]:
    acquires: List[ast.Call] = []
    releases = 0
    returns_acquire = False
    builds_token = False
    for node in _walk_own(fn):
        if isinstance(node, ast.Call):
            t = _call_tail(node)
            if t == "acquire":
                acquires.append(node)
            elif t in ("release", "unpin"):
                releases += 1
            elif t == "IOToken" or t.endswith("Token"):
                builds_token = True
        if isinstance(node, ast.Return) and node.value is not None:
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Call) and \
                        _call_tail(sub) == "acquire":
                    returns_acquire = True
    if acquires and not (releases or builds_token or returns_acquire):
        return [mod.finding(
            "BAM203", acquires[0],
            "`acquire` takes cache pins but this function neither "
            "releases them, returns the acquired state directly, nor "
            "binds them into an IOToken for the waiter — unpaired pins "
            "make the lines permanently unevictable")]
    return []
