"""bamverify — lowered-artifact static analysis for the BaM hot path.

bamlint (``tools/bamlint``) lints *source*; bamverify lints what XLA
actually *emitted*.  It enumerates the jit-cached op family via the
``iter_op_family()`` registry on ``BamArray``/``BamRuntime``, lowers each
op at canonical bucket shapes on the CPU backend, and checks the BAM5xx
rules against the compiled HLO text — silent donation drops, dtype creep,
callbacks escaping their ``lax.cond`` gate, scatter-count regressions,
and shape-bucketing executable leaks.  It then diffs a committed
**artifact manifest** (``tools/bamverify/manifest.json``: per op x bucket
-> scatter count, while-loop count, donation aliases, dtypes,
instruction count) so perf-relevant compiled-graph regressions are
caught structurally, without timing.

Run ``python -m tools.bamverify`` (CI gate) and
``python -m tools.bamverify --update-manifest`` after a deliberate
hot-path change.  See docs/static_analysis.md for the rule catalogue.

This ``__init__`` stays import-light (no JAX): ``tools/lint_docs.py``
imports ``ALL_RULES`` in jobs that never install dependencies.  Only
``tools.bamverify.lowering`` needs JAX.
"""
from __future__ import annotations

from tools.bamverify.rules import RULES as ALL_RULES

__all__ = ["ALL_RULES"]
