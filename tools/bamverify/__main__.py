"""CLI: ``python -m tools.bamverify [paths...]``.

Lowers the jit-cached op family at canonical bucket shapes on the CPU
backend, runs the BAM5xx rules over the compiled HLO, sweeps the
bucketed wrappers for executable leaks, and diffs the committed artifact
manifest (``tools/bamverify/manifest.json``).

Exit codes (shared convention with ``tools.bamlint``): ``0`` clean /
``--list-rules`` / ``--update-manifest``, ``1`` rule findings or
manifest drift, ``2`` usage or internal error.

``paths`` are accepted for CLI symmetry with bamlint (CI invokes both
the same way) and validated for existence, but artifact verification is
whole-program: it lowers the op family, it does not scan the files.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from tools.bamverify import ALL_RULES
from tools.bamverify.manifest import (
    MANIFEST_PATH, diff_manifest, entry_from_stats, load_manifest,
    save_manifest,
)
from tools.bamverify.rules import check_artifact

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.bamverify",
        description="BaM lowered-artifact verification (donation / dtype "
                    "/ callback-gating rules over compiled HLO, plus the "
                    "compiled-graph regression manifest).")
    ap.add_argument("paths", nargs="*",
                    help="accepted for symmetry with tools.bamlint; "
                         "verification always lowers the whole op family")
    ap.add_argument("--manifest", type=pathlib.Path, default=MANIFEST_PATH,
                    help="manifest file (default: tools/bamverify/"
                         "manifest.json)")
    ap.add_argument("--update-manifest", action="store_true",
                    help="record the current artifacts as the new baseline")
    ap.add_argument("--no-manifest", action="store_true",
                    help="skip the manifest diff (rules only)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(ALL_RULES):
            print(f"{rule}  {ALL_RULES[rule]}")
        return 0

    missing = [p for p in args.paths
               if not (pathlib.Path(p) if pathlib.Path(p).is_absolute()
                       else REPO_ROOT / p).exists()]
    if missing:
        print(f"bamverify: no such path(s): {', '.join(missing)}",
              file=sys.stderr)
        return 2

    try:
        # JAX import + lowering live behind the CLI entry so --list-rules
        # and usage errors never need the heavy dependency.
        from tools.bamverify.lowering import (
            canonical_array, canonical_runtime, collect_stats,
            lower_op_family, sweep_bucketed,
        )
        arr, st = canonical_array()
        rt, rst = canonical_runtime()
        artifacts = lower_op_family(arr, st) + lower_op_family(rt, rst)
        stats = collect_stats(artifacts)
    except Exception as e:                      # lowering is internal
        print(f"bamverify: internal error while lowering the op family: "
              f"{type(e).__name__}: {e}", file=sys.stderr)
        return 2

    current = {key: entry_from_stats(s) for key, s in stats.items()}
    if args.update_manifest:
        save_manifest(current, args.manifest)
        print(f"bamverify: wrote {len(current)} artifact entr(ies) to "
              f"{args.manifest}")

    recorded = {} if args.no_manifest else load_manifest(args.manifest)
    findings = []
    for spec, _txt in artifacts:
        findings.extend(check_artifact(
            spec, stats[spec.key], recorded.get(spec.key)))
    findings.extend(sweep_bucketed())

    drift = [] if (args.no_manifest or args.update_manifest) \
        else diff_manifest(recorded, current)

    for f in findings:
        print(f.render())
    for line in drift:
        print(f"manifest drift: {line}")
    n = len(findings) + len(drift)
    if n:
        print(f"\nbamverify: {len(findings)} rule finding(s), "
              f"{len(drift)} manifest drift line(s) across "
              f"{len(artifacts)} artifact(s)")
        return 1
    print(f"bamverify: clean ({len(artifacts)} artifacts verified"
          + ("" if args.no_manifest else ", manifest matches") + ")")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
