"""Produce lowered artifacts from the live op family (the JAX half).

The canonical configuration is deliberately small — lowering is about
*structure*, not throughput, and the compiled graph of ``submit`` at a
64-lane wavefront has the same scatter/while/callback anatomy as at
4096 — so the whole family lowers in well under a minute on CPU.

Canonical buckets: the first two of ``DEFAULT_BUCKETS``.  Larger buckets
change only shapes, not graph structure, and quadratically inflate
compile time of the coalescer's one-hot matmuls; the bucketed-sweep
check (BAM505) still exercises the full bucket table.
"""
from __future__ import annotations

import pathlib
import sys
from typing import Dict, Iterable, List, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

import jax                                    # noqa: E402
import numpy as np                            # noqa: E402

from repro.core.bam_array import (            # noqa: E402
    BamArray, BamRuntime, TenantSpec,
)
from tools.bamverify.rules import (           # noqa: E402
    ArtifactSpec, ArtifactStats, Finding, analyze_artifact,
    check_executable_count,
)

CANONICAL_BUCKETS: Tuple[int, ...] = (64, 256)

# Ragged batch sizes for the BAM505 bucketed sweep: none equal a bucket
# size, several map to the same bucket — a leak compiles one executable
# per size, a healthy bucketing at most one per bucket.
SWEEP_SIZES: Tuple[int, ...] = (3, 17, 40, 100, 200, 250)


def canonical_array() -> Tuple[BamArray, object]:
    """The small, fixed configuration every artifact is lowered at."""
    data = np.arange(4096, dtype=np.float32)
    return BamArray.build(data, block_elems=16, num_sets=16, ways=4,
                          num_queues=4, queue_depth=256)


def canonical_runtime() -> Tuple[BamRuntime, object]:
    """A two-tenant runtime for the per-tenant op family."""
    a = np.arange(1024, dtype=np.float32)
    b = np.arange(1024, dtype=np.float32) * 2
    return BamRuntime.build(
        [TenantSpec("a", a, block_elems=16),
         TenantSpec("b", b, block_elems=16)],
        num_sets=8, ways=4, num_queues=4, queue_depth=128)


def lower_op_family(owner, state,
                    buckets: Iterable[int] = CANONICAL_BUCKETS,
                    ) -> List[Tuple[ArtifactSpec, str]]:
    """Lower + compile every ``kind="jit"`` entry of ``owner``'s
    ``iter_op_family()`` registry (donated variants included) at each
    canonical bucket; returns ``(spec, compiled_hlo_text)`` pairs."""
    out: List[Tuple[ArtifactSpec, str]] = []
    for entry in owner.iter_op_family():
        if entry.kind != "jit":
            continue
        variants = (False, True) if entry.donatable else (False,)
        for donate in variants:
            fn = entry.get(donate=donate)
            for n in buckets:
                args = entry.example_args(state, n)
                lowered = fn.lower(*args)
                # pre-optimization IR (the jaxpr/StableHLO side): an f64
                # op DCE'd by XLA still means live dtype creep in source
                traced_f64 = "f64" in lowered.as_text()
                txt = lowered.compile().as_text()
                declared = (len(jax.tree_util.tree_leaves(args[0]))
                            if donate else 0)
                name = entry.name + ("[donated]" if donate else "")
                out.append((ArtifactSpec(
                    op=name, bucket=n, donated=donate,
                    declared_donated=declared,
                    pure_all_hit=entry.pure_all_hit,
                    traced_f64=traced_f64), txt))
    return out


def collect_stats(artifacts: List[Tuple[ArtifactSpec, str]]
                  ) -> Dict[str, ArtifactStats]:
    return {spec.key: analyze_artifact(txt) for spec, txt in artifacts}


def sweep_bucketed(sizes: Iterable[int] = SWEEP_SIZES) -> List[Finding]:
    """Drive the ``kind="bucketed"`` registry entries over a ragged batch
    sweep on a FRESH canonical instance (its jit cache starts empty, so
    trace counts are exactly the executable count), then apply BAM505."""
    arr, st = canonical_array()
    findings: List[Finding] = []
    for entry in arr.iter_op_family():
        if entry.kind != "bucketed":
            continue
        drive = entry.get()
        s = st
        for n in sizes:
            s = drive(s, n)
        for key in entry.trace_keys:
            findings.extend(check_executable_count(
                f"{entry.name}[{key}]", len(arr.buckets),
                arr.trace_counts.get(key, 0)))
    return findings
