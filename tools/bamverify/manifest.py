"""The compiled-graph regression manifest (JAX-free half).

``tools/bamverify/manifest.json`` records, per op x bucket, the
structural facts of every steady-state executable the BaM hot path
ships: serial scatter count, while-loop count, donation alias count,
dtypes present, and total instruction count.  It is the compiled-artifact
analogue of bamlint's ``baseline.json``: CI re-lowers the op family and
*diffs* the manifest, so a perf-relevant change to what XLA emits — a
scatter unfused, a donation dropped, a dtype widened, an executable
ballooning — fails structurally, without timing a single op.

Refresh after a deliberate hot-path change with::

    python -m tools.bamverify --update-manifest
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List

from tools.bamverify.rules import ArtifactStats

MANIFEST_PATH = pathlib.Path(__file__).resolve().parent / "manifest.json"

FIELDS = ("scatters", "while_loops", "donation_aliases", "dtypes",
          "instructions")


def entry_from_stats(stats: ArtifactStats) -> Dict:
    return {
        "scatters": stats.scatters,
        "while_loops": stats.while_loops,
        "donation_aliases": stats.donation_aliases,
        "dtypes": list(stats.dtypes),
        "instructions": stats.instructions,
    }


def load_manifest(path: pathlib.Path = MANIFEST_PATH) -> Dict[str, Dict]:
    if not path.is_file():
        return {}
    data = json.loads(path.read_text())
    return data.get("ops", {})


def save_manifest(entries: Dict[str, Dict],
                  path: pathlib.Path = MANIFEST_PATH) -> None:
    payload = {"version": 1, "ops": {k: entries[k] for k in sorted(entries)}}
    path.write_text(json.dumps(payload, indent=2) + "\n")


def diff_manifest(recorded: Dict[str, Dict],
                  current: Dict[str, Dict]) -> List[str]:
    """Readable per-op x bucket drift report (empty = manifests agree).

    Every line names the artifact and the field that moved — never a raw
    JSON dump — so a CI failure reads as "submit[donated]@64: scatters
    14 -> 17", not as a wall of text.
    """
    out: List[str] = []
    for key in sorted(set(recorded) | set(current)):
        if key not in current:
            out.append(f"{key}: recorded in the manifest but no longer "
                       "lowered (op removed or renamed? run "
                       "--update-manifest)")
            continue
        if key not in recorded:
            out.append(f"{key}: lowered but missing from the manifest "
                       "(new op/bucket — run --update-manifest)")
            continue
        rec, cur = recorded[key], current[key]
        for f in FIELDS:
            rv, cv = rec.get(f), cur.get(f)
            if rv != cv:
                out.append(f"{key}: {f} {rv} -> {cv}")
    return out
