"""BAM5xx rules over lowered artifacts (compiled HLO text).

Everything here is JAX-free: the rules consume HLO *text* (reusing the
instruction walk of :mod:`repro.launch.hlo_analysis`), so the whole rule
engine — including the committed golden fixtures under
``tools/bamverify/fixtures/`` — runs without compiling anything.  Only
:mod:`tools.bamverify.lowering` (which produces fresh artifacts from the
live op family) needs JAX.

An artifact is one compiled executable of one op at one canonical bucket
shape, described by :class:`ArtifactSpec` (what the op *declared*:
donation, purity contract) and measured into :class:`ArtifactStats`
(what XLA *emitted*: aliasing, dtypes, callbacks, scatters).  The rules
compare the two — plus, for BAM504, the committed manifest baseline.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import sys
from typing import Dict, List, Optional, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]
if str(REPO_ROOT / "src") not in sys.path:      # repro is a src-layout pkg
    sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.launch import hlo_analysis as H      # noqa: E402  (stdlib-only)

RULES = {
    "BAM501": "donation declared but the executable carries no "
              "input/output buffer aliasing — XLA silently dropped the "
              "donation, so every round copies the multi-MB state",
    "BAM502": "f64 instruction in a compiled hot-path executable "
              "(dtype creep that BAM303 could not see past lowering)",
    "BAM503": "host-callback custom-call executes unconditionally in an "
              "executable whose all-hit fast path must stay pure "
              "(the lax.cond fetch gate was compiled away or bypassed)",
    "BAM504": "serial scatter count above the recorded manifest baseline "
              "(a packed-scatter fusion regressed into per-field scatters)",
    "BAM505": "bucketed op compiled more executables than configured "
              "buckets (shape bucketing is leaking one executable per "
              "ragged batch size)",
}

# Host callbacks (jax.pure_callback / io_callback) lower to custom-calls
# whose target embeds "callback" on every backend we lower on.
CALLBACK_TARGET_MARKER = "callback"

# XLA:CPU lowers jnp scatter updates to scatter OR dynamic-update-slice
# (post-fusion); both serialize on CPU, so the "serial scatter" metric the
# PR 8 packed-scatter work optimized counts both forms.
SCATTER_OPS = ("scatter", "dynamic-update-slice")

_DTYPE_RE = re.compile(
    r"\b(pred|s4|s8|s16|s32|s64|u4|u8|u16|u32|u64|f8e4m3fn|f8e5m2|f8e4m3|"
    r"f8e3m4|f16|bf16|f32|f64|c64|c128)\[")
_ALIAS_ENTRY_RE = re.compile(r"(?:may|must)-alias")


@dataclasses.dataclass(frozen=True)
class ArtifactSpec:
    """What one lowered op *declared* (vs what XLA emitted)."""

    op: str                     # "submit[donated]", "wait", ...
    bucket: int                 # canonical wavefront size it was lowered at
    donated: bool = False       # jit carried donate_argnums for the state
    declared_donated: int = 0   # donated pytree leaves handed to jit
    pure_all_hit: bool = False  # callbacks must stay cond-gated (BAM503)
    traced_f64: bool = False    # f64 in the PRE-optimization lowering
                                # (jaxpr/StableHLO side): catches dtype
                                # creep even when XLA DCE'd the f64 op out
                                # of the final executable (BAM502)

    @property
    def key(self) -> str:
        return f"{self.op}@{self.bucket}"


@dataclasses.dataclass
class ArtifactStats:
    """Structural census of one compiled executable's HLO text."""

    scatters: int
    while_loops: int
    donation_aliases: int
    dtypes: List[str]
    instructions: int
    custom_call_targets: List[str]
    ungated_callbacks: List[str]    # callback targets outside any cond gate


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    key: str                    # artifact key ("submit[donated]@64") or op
    message: str

    def render(self) -> str:
        return f"{self.key}: {self.rule} {self.message}"


def analyze_artifact(hlo_text: str) -> ArtifactStats:
    """Measure the structural facts the BAM5xx rules and the manifest
    consume, with one parse of the compiled HLO text."""
    comps, entry = H.parse_computations(hlo_text)
    n_instr = 0
    n_scatter = 0
    n_while = 0
    for instrs in comps.values():
        for ins in instrs:
            n_instr += 1
            if ins.op in SCATTER_OPS:
                n_scatter += 1
            elif ins.op == "while":
                n_while += 1

    # input/output aliasing lives on the HloModule header (first line).
    header = hlo_text.splitlines()[0] if hlo_text else ""
    m = re.search(r"input_output_alias=\{(.*)$", header)
    n_alias = len(_ALIAS_ENTRY_RE.findall(m.group(1))) if m else 0

    dtypes = sorted({dm.group(1) for dm in _DTYPE_RE.finditer(hlo_text)})

    calls = H.iter_custom_calls(comps)
    targets = sorted({ins.custom_call_target for _, ins in calls})
    ungated_comps = H.ungated_computations(comps, entry)
    ungated = sorted({
        ins.custom_call_target for cname, ins in calls
        if CALLBACK_TARGET_MARKER in ins.custom_call_target
        and cname in ungated_comps})
    return ArtifactStats(
        scatters=n_scatter, while_loops=n_while, donation_aliases=n_alias,
        dtypes=dtypes, instructions=n_instr,
        custom_call_targets=targets, ungated_callbacks=ungated)


def check_artifact(spec: ArtifactSpec, hlo_text_or_stats,
                   baseline: Optional[Dict] = None) -> List[Finding]:
    """Run BAM501-BAM504 against one artifact.

    ``baseline`` is this artifact's committed manifest entry (or ``None``
    when there is nothing recorded yet — BAM504 then has no baseline to
    regress against and stays silent; the manifest *diff* still reports
    the missing entry).
    """
    stats = hlo_text_or_stats
    if isinstance(stats, str):
        stats = analyze_artifact(stats)
    out: List[Finding] = []
    if spec.donated and stats.donation_aliases == 0:
        out.append(Finding(
            "BAM501", spec.key,
            f"declared donation of {spec.declared_donated} state buffer(s) "
            "but the executable has no input/output aliasing — the "
            "donation was silently dropped (every round copies the state; "
            "check for shape/dtype mismatches between the donated input "
            "and the outputs)"))
    if "f64" in stats.dtypes or spec.traced_f64:
        where = ("compiled graph contains f64 instructions"
                 if "f64" in stats.dtypes else
                 "traced program contains f64 (optimized away in the "
                 "final executable, but the creep is live in source)")
        out.append(Finding(
            "BAM502", spec.key,
            f"{where} — a dtype-less constructor or x64 promotion "
            "survived lowering"))
    if spec.pure_all_hit and stats.ungated_callbacks:
        out.append(Finding(
            "BAM503", spec.key,
            "host callback custom-call(s) "
            f"{stats.ungated_callbacks} execute unconditionally — the "
            "all-hit fast path would pay a host round-trip every round; "
            "the fetch must stay behind its lax.cond gate"))
    if baseline is not None and stats.scatters > int(baseline["scatters"]):
        out.append(Finding(
            "BAM504", spec.key,
            f"serial scatter count {stats.scatters} exceeds the manifest "
            f"baseline {baseline['scatters']} — a packed scatter was "
            "split back into per-field updates; if intentional, run "
            "--update-manifest"))
    return out


def check_executable_count(op: str, n_buckets: int,
                           n_executables: int) -> List[Finding]:
    """BAM505: a bucketed op's jit cache may hold at most one executable
    per configured bucket; more means ragged batch sizes are leaking
    past the bucket padding and compiling per-size."""
    if n_executables > n_buckets:
        return [Finding(
            "BAM505", op,
            f"{n_executables} executables compiled for {n_buckets} "
            "configured buckets — ragged wavefronts are bypassing the "
            "bucket padding (one compile per batch size)")]
    return []


# ------------------------------------------------------------- fixtures
FIXTURE_HEADER = "bamverify-fixture:"


def parse_fixture_header(line: str) -> Tuple[str, Dict[str, int]]:
    """``// bamverify-fixture: expect BAM501 donated=17 pure_all_hit=0
    baseline_scatters=3`` -> ``("BAM501", {kwargs})``.  ``expect clean``
    marks a good fixture."""
    if FIXTURE_HEADER not in line:
        raise ValueError(f"not a bamverify fixture header: {line!r}")
    tail = line.split(FIXTURE_HEADER, 1)[1].split()
    if not tail or tail[0] != "expect":
        raise ValueError(f"fixture header missing 'expect': {line!r}")
    expected = tail[1]
    meta = {}
    for kv in tail[2:]:
        k, _, v = kv.partition("=")
        meta[k] = int(v)
    return expected, meta


def check_fixture(path: pathlib.Path) -> Tuple[str, List[Finding]]:
    """Run the rules against one committed golden fixture.

    ``.hlo`` fixtures carry a header comment describing the artifact's
    declared contract; ``.json`` fixtures feed the non-textual rules
    (BAM505's executable-count record).  Returns ``(expected_rule,
    findings)`` where expected is a rule id or ``"clean"``.
    """
    if path.suffix == ".json":
        data = json.loads(path.read_text())
        return data["expect"], check_executable_count(
            data["op"], data["n_buckets"], data["n_executables"])
    text = path.read_text()
    first, _, body = text.partition("\n")
    expected, meta = parse_fixture_header(first)
    spec = ArtifactSpec(
        op=path.stem, bucket=meta.get("bucket", 0),
        donated=bool(meta.get("donated", 0)),
        declared_donated=meta.get("donated", 0),
        pure_all_hit=bool(meta.get("pure_all_hit", 0)),
        traced_f64=bool(meta.get("traced_f64", 0)))
    baseline = None
    if "baseline_scatters" in meta:
        baseline = {"scatters": meta["baseline_scatters"]}
    return expected, check_artifact(spec, body, baseline)
