#!/usr/bin/env python
"""Docs lint: every script under benchmarks/ must be covered by
docs/benchmarks.md (mentioned by file name), and the core documentation
files must exist.  Exits nonzero with a list of violations — run from the
repo root; CI runs it on every push.
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
REQUIRED_DOCS = ["README.md", "docs/architecture.md", "docs/benchmarks.md",
                 "docs/testing.md", "docs/static_analysis.md"]


def main() -> int:
    errors = []
    for doc in REQUIRED_DOCS:
        if not (ROOT / doc).is_file():
            errors.append(f"missing required doc: {doc}")

    bench_doc = ROOT / "docs" / "benchmarks.md"
    text = bench_doc.read_text() if bench_doc.is_file() else ""
    for script in sorted((ROOT / "benchmarks").glob("*.py")):
        if script.name not in text:
            errors.append(
                f"benchmarks/{script.name} is not documented in "
                "docs/benchmarks.md")

    # every bamlint AND bamverify rule must be documented in
    # docs/static_analysis.md — the rule tables are the user-facing
    # contract for the CI gates (both ALL_RULES imports are JAX-free)
    sa_doc = ROOT / "docs" / "static_analysis.md"
    sa_text = sa_doc.read_text() if sa_doc.is_file() else ""
    sys.path.insert(0, str(ROOT))
    from tools.bamlint import ALL_RULES as LINT_RULES
    from tools.bamverify import ALL_RULES as VERIFY_RULES
    for tool, rules in (("bamlint", LINT_RULES),
                        ("bamverify", VERIFY_RULES)):
        for rule in sorted(rules):
            if rule not in sa_text:
                errors.append(
                    f"{tool} rule {rule} is not documented in "
                    "docs/static_analysis.md")

    for err in errors:
        print(f"docs-lint: {err}", file=sys.stderr)
    if not errors:
        print(f"docs-lint: OK ({len(REQUIRED_DOCS)} docs, all benchmarks "
              f"covered, {len(LINT_RULES)} bamlint + {len(VERIFY_RULES)} "
              "bamverify rules documented)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
